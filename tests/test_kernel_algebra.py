"""Composable kernel algebra: spec trees, parser, PSD, fused Pallas plan.

Property tests over randomly sampled KernelSpec trees (leaves rbf /
matern* / rq / linear; combinators Sum / Product / Scale): positive
semi-definiteness, agreement of the recursive evaluator with independently
composed leaf matrices, Matern -> RBF large-nu-style sanity limits, the
legacy (kind, GPParams) path staying bitwise, and the Pallas fused
multi-component plan (one HBM pass for a whole scalar-lengthscale sum;
single-component specs take exactly one fused pass — the pre-algebra
behavior).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep; deterministic fallback (conftest dir is on sys.path)
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import (
    GPParams,
    LEAF_KINDS,
    Leaf,
    Product,
    Scale,
    Sum,
    dense_khat,
    dense_mll,
    init_kernel_params,
    init_params,
    kernel_diag,
    kernel_matrix,
    noise_variance,
    num_components,
    parse_kernel,
    spec_expr,
    spec_from_json,
    spec_to_json,
)
from repro.core.kernels_math import leaf_matrix, softplus, sq_dist
from repro.kernels.ops import kmvm_block, mvm_plan
from repro.kernels.ref import kmvm_ref
from repro.train.solver_state import param_drift


def random_spec(r, depth=0):
    """Sample a small spec tree (leaves weighted so trees stay evaluable)."""
    choice = int(r.integers(0, 6))
    if depth >= 2 or choice < 3:
        leaf = Leaf(LEAF_KINDS[int(r.integers(0, len(LEAF_KINDS)))])
        if r.integers(0, 2):
            return Scale(leaf, float(r.uniform(0.2, 2.0)))
        return leaf
    if choice == 3:
        return Sum(tuple(random_spec(r, depth + 1)
                         for _ in range(int(r.integers(2, 4)))))
    if choice == 4:
        return Product(tuple(random_spec(r, depth + 1) for _ in range(2)))
    return Scale(random_spec(r, depth + 1), float(r.uniform(0.2, 2.0)))


def _compose_reference(spec, nodes, X1, X2):
    """Independent combinator walk: only `leaf_matrix` is shared with the
    implementation under test; Sum/Product/Scale semantics are re-derived
    here. Returns (K, nodes_consumed)."""
    if isinstance(spec, Leaf):
        return leaf_matrix(spec.kind, nodes[0], X1, X2), 1
    if isinstance(spec, Scale):
        K, used = _compose_reference(spec.inner, nodes[1:], X1, X2)
        return softplus(nodes[0].raw_outputscale) * K, used + 1
    kids = spec.terms if isinstance(spec, Sum) else spec.factors
    Ks, used = [], 0
    for k in kids:
        K, u = _compose_reference(k, nodes[used:], X1, X2)
        Ks.append(K)
        used += u
    if isinstance(spec, Sum):
        return sum(Ks[1:], Ks[0]), used
    out = Ks[0]
    for K in Ks[1:]:
        out = out * K
    return out, used


# ---------------------------------------------------------------------------
# parser + serialization
# ---------------------------------------------------------------------------


def test_parse_kernel_expressions():
    assert parse_kernel("matern32") == Leaf("matern32")
    assert parse_kernel("0.5*rbf") == Scale(Leaf("rbf"), 0.5)
    assert parse_kernel("scale(rq)") == Scale(Leaf("rq"))
    assert parse_kernel("0.5*rbf + matern32") == \
        Sum((Scale(Leaf("rbf"), 0.5), Leaf("matern32")))
    assert parse_kernel("rbf*linear") == Product((Leaf("rbf"), Leaf("linear")))
    assert parse_kernel("2*(rbf + linear)") == \
        Scale(Sum((Leaf("rbf"), Leaf("linear"))), 2.0)
    # precedence: * binds tighter than +
    assert parse_kernel("rbf*linear + rq") == \
        Sum((Product((Leaf("rbf"), Leaf("linear"))), Leaf("rq")))


@pytest.mark.parametrize("bad", ["", "foo", "0.5", "rbf +", "rbf * -1",
                                 "(rbf", "0*rbf"])
def test_parse_kernel_rejects(bad):
    with pytest.raises(ValueError):
        parse_kernel(bad)


@settings(deadline=None, max_examples=20)
@given(seed=st.integers(0, 2**16))
def test_spec_serialization_roundtrip(seed):
    spec = random_spec(np.random.default_rng(seed))
    assert spec_from_json(spec_to_json(spec)) == spec
    assert parse_kernel(spec_expr(spec)) == spec


# ---------------------------------------------------------------------------
# algebra semantics
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=15)
@given(seed=st.integers(0, 2**16), n=st.integers(8, 40))
def test_sampled_spec_trees_are_psd(seed, n):
    """Cholesky of K + sigma^2 I succeeds for any sampled spec tree (sums,
    products and scales of PSD kernels stay PSD; Schur product theorem)."""
    r = np.random.default_rng(seed)
    spec = random_spec(r)
    d = int(r.integers(1, 5))
    kp = init_kernel_params(spec, lengthscale=float(r.uniform(0.4, 1.5)),
                            noise=0.1, dtype=jnp.float64)
    X = jnp.asarray(r.normal(size=(n, d)))
    L = jnp.linalg.cholesky(dense_khat(spec, X, kp))
    assert bool(jnp.all(jnp.isfinite(L))), spec_expr(spec)


@settings(deadline=None, max_examples=15)
@given(seed=st.integers(0, 2**16))
def test_spec_eval_matches_composed_leaf_matrices(seed):
    """Sum/Product/Scale evaluation equals the dense composition of leaf
    matrices, and kernel_diag equals diag(kernel_matrix)."""
    r = np.random.default_rng(seed)
    spec = random_spec(r)
    kp = init_kernel_params(spec, dtype=jnp.float64)
    X1 = jnp.asarray(r.normal(size=(24, 3)))
    X2 = jnp.asarray(r.normal(size=(17, 3)))
    K = kernel_matrix(spec, X1, X2, kp)
    K_ref, used = _compose_reference(spec, list(kp.nodes), X1, X2)
    assert used == len(kp.nodes)
    np.testing.assert_allclose(np.asarray(K), np.asarray(K_ref),
                               rtol=1e-12, atol=1e-12)
    # kernel_diag is the EXACT diagonal; the dense matrix's diagonal goes
    # through the ||x||^2+||y||^2-2<x,y> cancellation, whose ~1e-15 absolute
    # d2 error a Matern sqrt amplifies to ~1e-7 — compare at that scale
    diag = kernel_diag(spec, X1, kp)
    np.testing.assert_allclose(
        np.asarray(diag), np.asarray(jnp.diagonal(kernel_matrix(spec, X1, X1, kp))),
        rtol=1e-6, atol=1e-6)


def test_matern_family_approaches_rbf():
    """Large-nu sanity limit: the Matern family's distance from the RBF
    shape shrinks monotonically in nu (1/2 -> 3/2 -> 5/2), pointwise over a
    distance grid."""
    d2 = jnp.asarray(np.linspace(1e-4, 4.0, 200))
    from repro.core.kernels_math import kernel_from_sqdist
    rbf = kernel_from_sqdist("rbf", d2)
    errs = [float(jnp.max(jnp.abs(kernel_from_sqdist(k, d2) - rbf)))
            for k in ("matern12", "matern32", "matern52")]
    assert errs[0] > errs[1] > errs[2], errs
    assert errs[2] < 0.1  # matern52 already tracks RBF to <0.1 on this grid


def test_legacy_gpparams_path_is_bitwise():
    """(kind, GPParams) still evaluates exactly the pre-algebra formula:
    outputscale * phi(d2(X/ls)) — bitwise, not just close."""
    r = np.random.default_rng(0)
    X1 = jnp.asarray(r.normal(size=(20, 3)), jnp.float32)
    X2 = jnp.asarray(r.normal(size=(15, 3)), jnp.float32)
    p = init_params(lengthscale=0.8, outputscale=1.3, noise=0.2)
    from repro.core.kernels_math import kernel_from_sqdist
    ls, os_ = softplus(p.raw_lengthscale), softplus(p.raw_outputscale)
    for kind in ("rbf", "matern32"):
        old = os_ * kernel_from_sqdist(kind, sq_dist(X1 / ls, X2 / ls))
        new = kernel_matrix(kind, X1, X2, p)
        np.testing.assert_array_equal(np.asarray(old), np.asarray(new))


def test_kernel_diag_dtype_follows_params_not_inputs():
    """bf16 inputs must not downcast the fp32 diag pivoted Cholesky uses;
    a linear leaf's input-dependent diag promotes through the params."""
    X16 = jnp.asarray(np.random.default_rng(0).normal(size=(8, 2)), jnp.bfloat16)
    p = init_params(noise=0.1)  # fp32 params
    assert kernel_diag("matern32", X16, p).dtype == jnp.float32
    spec = parse_kernel("rbf + 0.5*linear")
    kp = init_kernel_params(spec)
    d = kernel_diag(spec, X16, kp)
    assert d.dtype == jnp.float32
    # linear diag is input-dependent (non-constant)
    assert float(jnp.std(d.astype(jnp.float32))) > 0.0


def test_init_kernel_params_constrained_values():
    spec = parse_kernel("0.5*rbf + matern32")
    kp = init_kernel_params(spec, lengthscale=0.9, noise=0.2)
    s, rbf_ls, m32_ls = kp.nodes
    assert np.isclose(float(softplus(s.raw_outputscale)), 0.5, rtol=1e-6)
    assert np.isclose(float(softplus(rbf_ls.raw_lengthscale)), 0.9, rtol=1e-6)
    assert np.isclose(float(softplus(m32_ls.raw_lengthscale)), 0.9, rtol=1e-6)
    assert np.isclose(float(noise_variance(kp, 0.0)), 0.2, rtol=1e-5)
    assert num_components(spec) == 2


def test_param_drift_over_flattened_pytree():
    spec = parse_kernel("0.5*rbf + matern32")
    kp = init_kernel_params(spec)
    assert param_drift(kp, kp) == 0.0
    # moving ANY node registers; moving only the mean does not
    moved = kp._replace(nodes=(kp.nodes[0],
                               kp.nodes[1]._replace(
                                   raw_lengthscale=kp.nodes[1].raw_lengthscale + 1.0),
                               kp.nodes[2]))
    assert param_drift(kp, moved) > 0.1
    mean_only = kp._replace(raw_mean=kp.raw_mean + 5.0)
    assert param_drift(kp, mean_only) == 0.0
    # legacy GPParams keeps its historical behavior
    p = init_params(noise=0.3)
    assert param_drift(p, p._replace(raw_mean=p.raw_mean + 5.0)) == 0.0
    assert param_drift(p, p._replace(raw_noise=p.raw_noise + 1.0)) > 0.1


# ---------------------------------------------------------------------------
# fused Pallas plan + execution
# ---------------------------------------------------------------------------


def test_single_component_takes_exactly_one_fused_pass():
    """No per-component regression: legacy pairs and bare leaves plan to
    ONE Pallas pass, nothing else."""
    p = init_params(noise=0.3)
    plan = mvm_plan("matern32", p)
    assert plan.num_fused_passes == 1
    assert plan.passes[0].components == (("matern32",),)
    assert plan.linear_terms == () and plan.num_fallback_terms == 0

    spec = parse_kernel("scale(rq)")
    kp = init_kernel_params(spec)
    plan = mvm_plan(spec, kp)
    assert plan.num_fused_passes == 1 and plan.num_fallback_terms == 0


def test_scalar_lengthscale_sum_fuses_into_one_pass():
    """The whole sum kernel costs ONE pass over HBM when every component's
    lengthscale is shared-scalar; ARD components fall back to their own
    fused pass; linear terms never enter Pallas at all."""
    spec = parse_kernel("0.5*rbf + matern32 + scale(rq)")
    kp = init_kernel_params(spec)
    plan = mvm_plan(spec, kp)
    assert plan.num_fused_passes == 1
    assert plan.passes[0].components == (("rbf",), ("matern32",), ("rq",))
    assert plan.num_fallback_terms == 0

    # ARD components get their own metric -> their own pass
    spec = parse_kernel("rbf + matern32")
    kp_ard = init_kernel_params(spec, ard_dims=3)
    plan = mvm_plan(spec, kp_ard)
    assert plan.num_fused_passes == 2

    # pure linear terms are thin matmuls outside Pallas
    spec = parse_kernel("rbf + 0.5*linear")
    kp = init_kernel_params(spec)
    plan = mvm_plan(spec, kp)
    assert plan.num_fused_passes == 1 and len(plan.linear_terms) == 1

    # linear x stationary products use the dense-slab fallback
    spec = parse_kernel("rbf*linear")
    kp = init_kernel_params(spec)
    plan = mvm_plan(spec, kp)
    assert plan.num_fused_passes == 0 and plan.num_fallback_terms == 1


@pytest.mark.parametrize("expr", [
    "0.5*rbf + matern32",
    "0.5*rbf + matern32 + scale(rq)",
    "rbf*matern52 + 0.3*matern12",
    "rbf + 0.5*linear",
    "rbf*linear + matern32",
])
def test_fused_multicomponent_matches_dense(expr):
    """Acceptance: the Pallas (interpret) fused multi-component MVM matches
    the dense reference within 2e-5 fp32."""
    spec = parse_kernel(expr)
    kp = init_kernel_params(spec, lengthscale=0.8, noise=0.2)
    r = np.random.default_rng(abs(hash(expr)) % 2**31)
    Xi = jnp.asarray(r.normal(size=(100, 5)), jnp.float32)
    Xj = jnp.asarray(r.normal(size=(130, 5)), jnp.float32)
    V = jnp.asarray(r.normal(size=(130, 3)), jnp.float32)
    out = kmvm_block(spec, Xi, Xj, V, kp, interpret=True)
    ref = kmvm_ref(spec, Xi, Xj, V, kp)
    scale = float(jnp.max(jnp.abs(ref))) + 1.0
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5 * scale)


@settings(deadline=None, max_examples=10)
@given(seed=st.integers(0, 2**16))
def test_fused_random_specs_match_dense(seed):
    r = np.random.default_rng(seed)
    spec = random_spec(r)
    kp = init_kernel_params(spec, lengthscale=float(r.uniform(0.5, 1.2)),
                            noise=0.2)
    Xi = jnp.asarray(r.normal(size=(40, 3)), jnp.float32)
    Xj = jnp.asarray(r.normal(size=(60, 3)), jnp.float32)
    V = jnp.asarray(r.normal(size=(60, 2)), jnp.float32)
    out = kmvm_block(spec, Xi, Xj, V, kp, interpret=True)
    ref = kmvm_ref(spec, Xi, Xj, V, kp)
    scale = float(jnp.max(jnp.abs(ref))) + 1.0
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-5, atol=5e-5 * scale,
                               err_msg=spec_expr(spec))


def test_sharded_composite_mll_matches_oracle():
    """The fourth backend: Sum(Scale(rbf), matern32) solves on the sharded
    engine (1-device mesh, in-process) and tracks the dense-Cholesky oracle
    on value and the probe-free raw_mean gradient."""
    from repro.core.distributed import (
        DistMLLConfig, make_geometry, make_mll_value_and_grad, replicate,
        shard_vector,
    )
    spec = Sum((Scale(Leaf("rbf")), Leaf("matern32")))
    n, d = 128, 3
    r = np.random.default_rng(2)
    X = jnp.asarray(r.normal(size=(n, d)))
    y = jnp.asarray(np.sin(np.asarray(X) @ r.normal(size=d))
                    + 0.1 * r.normal(size=n))
    kp = init_kernel_params(spec, noise=0.3, dtype=jnp.float64)
    mesh = jax.make_mesh((1,), ("data",))
    geom = make_geometry(mesh, n, d, mode="1d", row_block=32)
    cfg = DistMLLConfig(kernel=spec, precond_rank=30, num_probes=64,
                        max_cg_iters=200, cg_tol=1e-8)
    vg = make_mll_value_and_grad(mesh, geom, cfg)
    loss, _, grads = vg(replicate(mesh, X), shard_vector(mesh, geom, y),
                        replicate(mesh, kp), jax.random.PRNGKey(0))
    oracle_loss, g_oracle = jax.value_and_grad(
        lambda p: -dense_mll(spec, X, y, p) / n)(kp)
    assert abs(float(loss) - float(oracle_loss)) < \
        2e-2 * abs(float(oracle_loss)) + 1e-3
    assert abs(float(grads.raw_mean) - float(g_oracle.raw_mean)) < 1e-6


def test_ard_composite_operators_agree():
    """ARD lengthscales per component: dense vs partitioned vs pallas."""
    from repro.core import OperatorConfig, make_operator
    spec = parse_kernel("rbf + matern32")
    kp = init_kernel_params(spec, ard_dims=3, noise=0.3)
    r = np.random.default_rng(5)
    X = jnp.asarray(r.normal(size=(64, 3)), jnp.float32)
    V = jnp.asarray(r.normal(size=(64, 2)), jnp.float32)
    ref = dense_khat(spec, X, kp) @ V
    for backend in ("dense", "partitioned", "pallas"):
        op = make_operator(OperatorConfig(kernel=spec, backend=backend,
                                          row_block=32, interpret=True), X, kp)
        np.testing.assert_allclose(np.asarray(op.matvec(V)), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4, err_msg=backend)
