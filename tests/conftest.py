"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see 1 real CPU device;
only launch/dryrun.py (and explicit subprocess tests) request 512/8 fake
devices."""

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_gp_data(rng, n=200, d=4, noise=0.1, dtype="float64"):
    import jax.numpy as jnp

    X = jnp.asarray(rng.normal(size=(n, d)).astype(dtype))
    w = rng.normal(size=(d,))
    y = jnp.asarray((np.sin(np.asarray(X) @ w) +
                     noise * rng.normal(size=n)).astype(dtype))
    return X, y


@pytest.fixture
def gp_data(rng):
    return make_gp_data(rng)
