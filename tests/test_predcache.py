"""Prediction caches: mean cache, LOVE variance cache, exact variance."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ExactGP, ExactGPConfig, dense_khat, init_params, kernel_diag,
    kernel_matrix,
)

CFG = ExactGPConfig(kernel="matern32", precond_rank=30, row_block=32,
                    lanczos_rank=100, pred_max_cg_iters=300, pred_cg_tol=1e-4)


def _oracle(X, y, Xs, params):
    Khat = dense_khat("matern32", X, params)
    Ks = kernel_matrix("matern32", Xs, X, params)
    mean = Ks @ jnp.linalg.solve(Khat, y)
    var = kernel_diag("matern32", Xs, params) - jnp.sum(
        Ks * jnp.linalg.solve(Khat, Ks.T).T, axis=1)
    return mean, var


def test_predictive_mean_matches_closed_form(gp_data, rng):
    X, y = gp_data
    params = init_params(noise=0.2, dtype=jnp.float64)
    gp = ExactGP(CFG)
    cache = gp.precompute(X, y, params, jax.random.PRNGKey(0))
    Xs = jnp.asarray(rng.normal(size=(25, X.shape[1])))
    mean, _ = gp.predict(X, Xs, params, cache)
    mean_o, _ = _oracle(X, y, Xs, params)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(mean_o), atol=1e-3)


def test_exact_variance_matches_closed_form(gp_data, rng):
    X, y = gp_data
    params = init_params(noise=0.2, dtype=jnp.float64)
    gp = ExactGP(CFG)
    cache = gp.precompute(X, y, params, jax.random.PRNGKey(0))
    Xs = jnp.asarray(rng.normal(size=(15, X.shape[1])))
    _, var = gp.predict(X, Xs, params, cache, exact_variance=True,
                        include_noise=False)
    _, var_o = _oracle(X, y, Xs, params)
    np.testing.assert_allclose(np.asarray(var), np.asarray(var_o), rtol=1e-3,
                               atol=1e-6)


def test_cached_variance_upper_bounds_exact(gp_data, rng):
    """LOVE cache truncates the subtracted correction -> var_cached >= var."""
    X, y = gp_data
    params = init_params(noise=0.2, dtype=jnp.float64)
    gp = ExactGP(CFG._replace(lanczos_rank=40))
    cache = gp.precompute(X, y, params, jax.random.PRNGKey(0))
    Xs = jnp.asarray(rng.normal(size=(20, X.shape[1])))
    _, var_c = gp.predict(X, Xs, params, cache, include_noise=False)
    _, var_o = _oracle(X, y, Xs, params)
    assert np.all(np.asarray(var_c) >= np.asarray(var_o) - 1e-6)


def test_cached_variance_converges_with_rank(gp_data, rng):
    X, y = gp_data
    params = init_params(noise=0.2, dtype=jnp.float64)
    Xs = jnp.asarray(rng.normal(size=(20, X.shape[1])))
    _, var_o = _oracle(X, y, Xs, params)
    errs = []
    for rank in (10, 50, 150):
        gp = ExactGP(CFG._replace(lanczos_rank=rank))
        cache = gp.precompute(X, y, params, jax.random.PRNGKey(0))
        _, var_c = gp.predict(X, Xs, params, cache, include_noise=False)
        errs.append(float(np.abs(np.asarray(var_c) - np.asarray(var_o)).max()))
    assert errs[-1] <= errs[0] + 1e-9
    # single-probe Lanczos subspace: a loose absolute cap; the monotone
    # improvement above is the functional check (exact path covers accuracy)
    assert errs[-1] < 6e-2


def test_cache_state_forced_fp32_under_reduced_precision_operands(rng):
    """Regression: the Lanczos probe / CG state used to inherit op.dtype —
    with bf16-stored inputs (and the bf16 compute fast path) the caches
    themselves went reduced-precision. solver_dtype forces >= fp32."""
    from repro.core import OperatorConfig, make_operator
    from repro.core.predcache import build_prediction_cache

    X = jnp.asarray(rng.normal(size=(64, 3)), jnp.bfloat16)
    y = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    op = make_operator(
        OperatorConfig(kernel="matern32", backend="partitioned",
                       row_block=32, compute_dtype="bfloat16"),
        X, init_params(noise=0.2, dtype=jnp.float32))
    assert op.dtype == jnp.bfloat16  # the hazard this test guards
    cache = build_prediction_cache(op, y, jax.random.PRNGKey(0),
                                   precond_rank=10, lanczos_rank=20,
                                   pred_tol=0.05, max_cg_iters=100)
    assert cache.mean_cache.dtype == jnp.float32
    assert cache.var_Q.dtype == jnp.float32
    assert cache.var_T_chol.dtype == jnp.float32
    assert np.all(np.isfinite(np.asarray(cache.mean_cache, np.float32)))


def test_exact_variance_chunked_matches_unchunked(gp_data, rng):
    """mBCG columns are independent -> chunking over Xstar is exact."""
    from repro.core import OperatorConfig, make_operator
    from repro.core.predcache import predict_var_exact

    X, y = gp_data
    params = init_params(noise=0.2, dtype=jnp.float64)
    op = make_operator(OperatorConfig(kernel="matern32",
                                      backend="partitioned", row_block=32),
                       X, params)
    Xs = jnp.asarray(rng.normal(size=(33, X.shape[1])))
    kw = dict(precond_rank=30, pred_tol=1e-4, max_cg_iters=300)
    v_all = predict_var_exact(op, Xs, xstar_chunk=None, **kw)
    v_chk = predict_var_exact(op, Xs, xstar_chunk=7, **kw)
    np.testing.assert_allclose(np.asarray(v_chk), np.asarray(v_all),
                               rtol=1e-8)


def test_prediction_reuses_cache_without_solves(gp_data, rng):
    """After precompute, predict() must not run CG (mean path is one MVM):
    verified by jaxpr containing no while/scan over CG state."""
    X, y = gp_data
    params = init_params(noise=0.2, dtype=jnp.float64)
    gp = ExactGP(CFG)
    cache = gp.precompute(X, y, params, jax.random.PRNGKey(0))
    Xs = jnp.asarray(rng.normal(size=(5, X.shape[1])))
    from repro.core.predcache import predict_mean
    op = gp.operator(X, params)
    jaxpr = jax.make_jaxpr(lambda xs: predict_mean(op, xs, cache))(Xs)
    assert "while" not in str(jaxpr) and "scan" not in str(jaxpr)
