"""Prediction caches: mean cache, LOVE variance cache, exact variance."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ExactGP, ExactGPConfig, dense_khat, init_params, kernel_diag,
    kernel_matrix,
)

CFG = ExactGPConfig(kernel="matern32", precond_rank=30, row_block=32,
                    lanczos_rank=100, pred_max_cg_iters=300, pred_cg_tol=1e-4)


def _oracle(X, y, Xs, params):
    Khat = dense_khat("matern32", X, params)
    Ks = kernel_matrix("matern32", Xs, X, params)
    mean = Ks @ jnp.linalg.solve(Khat, y)
    var = kernel_diag("matern32", Xs, params) - jnp.sum(
        Ks * jnp.linalg.solve(Khat, Ks.T).T, axis=1)
    return mean, var


def test_predictive_mean_matches_closed_form(gp_data, rng):
    X, y = gp_data
    params = init_params(noise=0.2, dtype=jnp.float64)
    gp = ExactGP(CFG)
    cache = gp.precompute(X, y, params, jax.random.PRNGKey(0))
    Xs = jnp.asarray(rng.normal(size=(25, X.shape[1])))
    mean, _ = gp.predict(X, Xs, params, cache)
    mean_o, _ = _oracle(X, y, Xs, params)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(mean_o), atol=1e-3)


def test_exact_variance_matches_closed_form(gp_data, rng):
    X, y = gp_data
    params = init_params(noise=0.2, dtype=jnp.float64)
    gp = ExactGP(CFG)
    cache = gp.precompute(X, y, params, jax.random.PRNGKey(0))
    Xs = jnp.asarray(rng.normal(size=(15, X.shape[1])))
    _, var = gp.predict(X, Xs, params, cache, exact_variance=True,
                        include_noise=False)
    _, var_o = _oracle(X, y, Xs, params)
    np.testing.assert_allclose(np.asarray(var), np.asarray(var_o), rtol=1e-3,
                               atol=1e-6)


def test_cached_variance_upper_bounds_exact(gp_data, rng):
    """LOVE cache truncates the subtracted correction -> var_cached >= var."""
    X, y = gp_data
    params = init_params(noise=0.2, dtype=jnp.float64)
    gp = ExactGP(CFG._replace(lanczos_rank=40))
    cache = gp.precompute(X, y, params, jax.random.PRNGKey(0))
    Xs = jnp.asarray(rng.normal(size=(20, X.shape[1])))
    _, var_c = gp.predict(X, Xs, params, cache, include_noise=False)
    _, var_o = _oracle(X, y, Xs, params)
    assert np.all(np.asarray(var_c) >= np.asarray(var_o) - 1e-6)


def test_cached_variance_converges_with_rank(gp_data, rng):
    X, y = gp_data
    params = init_params(noise=0.2, dtype=jnp.float64)
    Xs = jnp.asarray(rng.normal(size=(20, X.shape[1])))
    _, var_o = _oracle(X, y, Xs, params)
    errs = []
    for rank in (10, 50, 150):
        gp = ExactGP(CFG._replace(lanczos_rank=rank))
        cache = gp.precompute(X, y, params, jax.random.PRNGKey(0))
        _, var_c = gp.predict(X, Xs, params, cache, include_noise=False)
        errs.append(float(np.abs(np.asarray(var_c) - np.asarray(var_o)).max()))
    assert errs[-1] <= errs[0] + 1e-9
    # single-probe Lanczos subspace: a loose absolute cap; the monotone
    # improvement above is the functional check (exact path covers accuracy)
    assert errs[-1] < 6e-2


def test_cache_state_forced_fp32_under_reduced_precision_operands(rng):
    """Regression: the Lanczos probe / CG state used to inherit op.dtype —
    with bf16-stored inputs (and the bf16 compute fast path) the caches
    themselves went reduced-precision. solver_dtype forces >= fp32."""
    from repro.core import OperatorConfig, make_operator
    from repro.core.predcache import build_prediction_cache

    X = jnp.asarray(rng.normal(size=(64, 3)), jnp.bfloat16)
    y = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    op = make_operator(
        OperatorConfig(kernel="matern32", backend="partitioned",
                       row_block=32, compute_dtype="bfloat16"),
        X, init_params(noise=0.2, dtype=jnp.float32))
    assert op.dtype == jnp.bfloat16  # the hazard this test guards
    cache = build_prediction_cache(op, y, jax.random.PRNGKey(0),
                                   precond_rank=10, lanczos_rank=20,
                                   pred_tol=0.05, max_cg_iters=100)
    assert cache.mean_cache.dtype == jnp.float32
    assert cache.var_Q.dtype == jnp.float32
    assert cache.var_T_chol.dtype == jnp.float32
    assert np.all(np.isfinite(np.asarray(cache.mean_cache, np.float32)))


def test_exact_variance_chunked_matches_unchunked(gp_data, rng):
    """mBCG columns are independent -> chunking over Xstar is exact."""
    from repro.core import OperatorConfig, make_operator
    from repro.core.predcache import predict_var_exact

    X, y = gp_data
    params = init_params(noise=0.2, dtype=jnp.float64)
    op = make_operator(OperatorConfig(kernel="matern32",
                                      backend="partitioned", row_block=32),
                       X, params)
    Xs = jnp.asarray(rng.normal(size=(33, X.shape[1])))
    kw = dict(precond_rank=30, pred_tol=1e-4, max_cg_iters=300)
    v_all = predict_var_exact(op, Xs, xstar_chunk=None, **kw)
    v_chk = predict_var_exact(op, Xs, xstar_chunk=7, **kw)
    np.testing.assert_allclose(np.asarray(v_chk), np.asarray(v_all),
                               rtol=1e-8)


def test_prediction_reuses_cache_without_solves(gp_data, rng):
    """After precompute, predict() must not run CG (mean path is one MVM):
    verified by jaxpr containing no while/scan over CG state."""
    X, y = gp_data
    params = init_params(noise=0.2, dtype=jnp.float64)
    gp = ExactGP(CFG)
    cache = gp.precompute(X, y, params, jax.random.PRNGKey(0))
    Xs = jnp.asarray(rng.normal(size=(5, X.shape[1])))
    from repro.core.predcache import predict_mean
    op = gp.operator(X, params)
    jaxpr = jax.make_jaxpr(lambda xs: predict_mean(op, xs, cache))(Xs)
    assert "while" not in str(jaxpr) and "scan" not in str(jaxpr)


# ---------------------------------------------------------------------------
# incremental updates (update_prediction_cache)
# ---------------------------------------------------------------------------


def _stream_data(rng, n0=160, m=16, k=3, d=4):
    """(X_full, y_full) covering n0 + k*m rows of one smooth function."""
    n = n0 + k * m
    X = jnp.asarray(rng.normal(size=(n, d)))
    w = rng.normal(size=(d,))
    y = jnp.asarray(np.sin(np.asarray(X) @ w) + 0.1 * rng.normal(size=n))
    return X, y


def _op(backend, X, params):
    from repro.core import OperatorConfig, make_operator
    return make_operator(
        OperatorConfig(kernel="matern32", backend=backend, row_block=32),
        X, params)


@pytest.mark.parametrize("backend", ["dense", "partitioned"])
def test_update_over_batches_matches_cold_refit(rng, backend):
    """k sequential m-row updates == one cold refit on the full data, for
    BOTH served quantities (mean and LOVE variance), within the paper's
    prediction tolerance. Lanczos rank is kept near n so the comparison
    pins the update algebra, not the shared LOVE truncation error."""
    from repro.core.predcache import (
        build_prediction_cache, predict_mean, predict_var_cached,
        update_prediction_cache,
    )

    n0, m, k = 160, 16, 3
    X, y = _stream_data(rng, n0=n0, m=m, k=k)
    params = init_params(noise=0.2, dtype=jnp.float64)
    kw = dict(precond_rank=40, lanczos_rank=200, pred_tol=0.01)

    op0 = _op(backend, X[:n0], params)
    cache = build_prediction_cache(op0, y[:n0], jax.random.PRNGKey(0), **kw)
    precond = None
    for i in range(k):
        n_i = n0 + (i + 1) * m
        op_i = _op(backend, X[:n_i], params)
        res = update_prediction_cache(op_i, y[:n_i], cache,
                                      jax.random.PRNGKey(i + 1),
                                      precond=precond, **kw)
        cache, precond = res.cache, res.precond
        assert res.num_new == m

    n = n0 + k * m
    op = _op(backend, X, params)
    cold = build_prediction_cache(op, y, jax.random.PRNGKey(9), **kw)
    Xs = jnp.asarray(rng.normal(size=(25, X.shape[1])))
    np.testing.assert_allclose(
        np.asarray(predict_mean(op, Xs, cache)),
        np.asarray(predict_mean(op, Xs, cold)), atol=5e-2)
    np.testing.assert_allclose(
        np.asarray(predict_var_cached(op, Xs, cache)),
        np.asarray(predict_var_cached(op, Xs, cold)), atol=5e-2)
    assert cache.mean_cache.shape == (n,)
    # rank grew by m per non-compacted batch (Lanczos rank is capped at n0)
    assert cache.var_Q.shape[1] == min(kw["lanczos_rank"], n0) + k * m


def test_update_warm_solve_cheaper_than_cold(rng):
    """The warm-started update must apply FEWER CG iterations than a cold
    solve of the same extended system at the same tolerance — the claim
    behind the update's O(n*m) cost."""
    from repro.core.pcg import pcg
    from repro.core.predcache import (
        build_prediction_cache, update_prediction_cache,
    )

    n0, m = 160, 16
    X, y = _stream_data(rng, n0=n0, m=m, k=1)
    params = init_params(noise=0.2, dtype=jnp.float64)
    op0 = _op("partitioned", X[:n0], params)
    cache = build_prediction_cache(op0, y[:n0], jax.random.PRNGKey(0),
                                   precond_rank=40, lanczos_rank=80,
                                   pred_tol=0.01)
    op = _op("partitioned", X, params)
    res = update_prediction_cache(op, y, cache, jax.random.PRNGKey(1),
                                  precond_rank=40, lanczos_rank=80,
                                  pred_tol=0.01)
    warm_iters = int(np.max(np.asarray(res.mean_iters)))
    from repro.core.kernels_math import constant_mean
    precond = op.preconditioner(40)
    yc = y - constant_mean(op.params)
    cold = pcg(op, yc[:, None], precond.solve, max_iters=400, min_iters=1,
               tol=0.01)
    cold_iters = int(np.max(np.asarray(cold.iterations)))
    assert warm_iters < cold_iters
    assert float(jnp.max(res.cache.solve_rel_residual)) <= 0.01


def test_update_compaction_refreshes_variance(rng):
    """Once the grown rank would exceed max_rank the update re-runs the
    full Lanczos pass (variance_refreshed) and the rank resets."""
    from repro.core.predcache import (
        build_prediction_cache, update_prediction_cache,
    )

    n0, m = 160, 16
    X, y = _stream_data(rng, n0=n0, m=m, k=1)
    params = init_params(noise=0.2, dtype=jnp.float64)
    op0 = _op("partitioned", X[:n0], params)
    cache = build_prediction_cache(op0, y[:n0], jax.random.PRNGKey(0),
                                   precond_rank=40, lanczos_rank=60,
                                   pred_tol=0.01)
    op = _op("partitioned", X, params)
    res = update_prediction_cache(op, y, cache, jax.random.PRNGKey(1),
                                  precond_rank=40, lanczos_rank=60,
                                  max_rank=64, pred_tol=0.01)
    assert res.variance_refreshed
    assert res.cache.var_Q.shape == (n0 + m, 60)


def test_update_rejects_non_grown_operator(rng):
    from repro.core.predcache import (
        build_prediction_cache, update_prediction_cache,
    )

    n0 = 64
    X, y = _stream_data(rng, n0=n0, m=0, k=0)
    params = init_params(noise=0.2, dtype=jnp.float64)
    op = _op("dense", X, params)
    cache = build_prediction_cache(op, y, jax.random.PRNGKey(0),
                                   precond_rank=20, lanczos_rank=30)
    with pytest.raises(ValueError, match="at least one new row"):
        update_prediction_cache(op, y, cache, jax.random.PRNGKey(1))
