"""PCG/mBCG: solve accuracy, pipelined equivalence, convergence masking."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep; deterministic fallback (conftest dir is on sys.path)
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import (
    dense_khat, init_params, kmvm, make_preconditioner, pcg,
)

P64 = dict(dtype=jnp.float64)


def _setup(rng, n=120, d=3, noise=0.3):
    X = jnp.asarray(rng.normal(size=(n, d)))
    params = init_params(noise=noise, **P64)
    Khat = dense_khat("matern32", X, params)
    mvm = lambda V: kmvm("matern32", X, V, params, row_block=32)
    return X, params, Khat, mvm


def test_pcg_matches_direct_solve(rng):
    X, params, Khat, mvm = _setup(rng)
    B = jnp.asarray(rng.normal(size=(X.shape[0], 3)))
    pre = make_preconditioner("matern32", X, params, 30)
    res = pcg(mvm, B, pre.solve, max_iters=200, tol=1e-10, min_iters=5)
    direct = jnp.linalg.solve(Khat, B)
    np.testing.assert_allclose(np.asarray(res.solution), np.asarray(direct),
                               atol=1e-6)
    assert np.all(np.asarray(res.rel_residual) < 1e-8)


def test_pipelined_equals_standard(rng):
    X, params, Khat, mvm = _setup(rng)
    B = jnp.asarray(rng.normal(size=(X.shape[0], 2)))
    pre = make_preconditioner("matern32", X, params, 30)
    r1 = pcg(mvm, B, pre.solve, max_iters=150, tol=1e-10, min_iters=5)
    r2 = pcg(mvm, B, pre.solve, max_iters=150, tol=1e-10, min_iters=5,
             method="pipelined")
    np.testing.assert_allclose(np.asarray(r1.solution),
                               np.asarray(r2.solution), atol=1e-6)


def test_preconditioner_reduces_iterations(rng):
    X, params, Khat, mvm = _setup(rng, n=200, noise=0.05)
    y = jnp.asarray(rng.normal(size=(X.shape[0], 1)))
    r_no = pcg(mvm, y, None, max_iters=300, tol=1e-6, min_iters=2)
    pre = make_preconditioner("matern32", X, params, 60)
    r_pre = pcg(mvm, y, pre.solve, max_iters=300, tol=1e-6, min_iters=2)
    assert int(r_pre.iterations[0]) < int(r_no.iterations[0])


def test_convergence_masking_freezes_columns(rng):
    """A converged column's coefficients are zeroed; others keep iterating."""
    X, params, Khat, mvm = _setup(rng)
    easy = np.zeros((X.shape[0], 1))
    easy[0] = 1e-3
    hard = rng.normal(size=(X.shape[0], 1))
    B = jnp.asarray(np.concatenate([easy, hard], 1))
    res = pcg(mvm, B, None, max_iters=100, tol=1e-4, min_iters=2)
    assert int(res.iterations[0]) <= int(res.iterations[1])
    # frozen iterations have alpha == 0
    n_active0 = int(res.iterations[0])
    assert np.allclose(np.asarray(res.alphas)[n_active0:, 0], 0.0)


def test_1d_rhs_roundtrip(rng):
    X, params, Khat, mvm = _setup(rng)
    y = jnp.asarray(rng.normal(size=X.shape[0]))
    res = pcg(mvm, y, None, max_iters=200, tol=1e-10, min_iters=5)
    assert res.solution.shape == y.shape
    np.testing.assert_allclose(np.asarray(res.solution),
                               np.asarray(jnp.linalg.solve(Khat, y)), atol=1e-6)


@settings(deadline=None, max_examples=8)
@given(seed=st.integers(0, 2**16), t=st.integers(1, 4),
       method=st.sampled_from(["standard", "pipelined"]))
def test_pcg_property_random_spd(seed, t, method):
    """Property: for any kernel SPD system, PCG @ tight tol == direct solve."""
    rng = np.random.default_rng(seed)
    X, params, Khat, mvm = _setup(rng, n=64, noise=0.5)
    B = jnp.asarray(rng.normal(size=(64, t)))
    res = pcg(mvm, B, None, max_iters=200, tol=1e-11, min_iters=5,
              method=method)
    np.testing.assert_allclose(np.asarray(res.solution),
                               np.asarray(jnp.linalg.solve(Khat, B)),
                               atol=1e-5)


def test_loose_tolerance_stops_early(rng):
    """Paper: eps = 1 training tolerance => far fewer iterations."""
    X, params, Khat, mvm = _setup(rng, n=200, noise=0.1)
    y = jnp.asarray(rng.normal(size=(X.shape[0], 1)))
    pre = make_preconditioner("matern32", X, params, 30)
    loose = pcg(mvm, y, pre.solve, max_iters=200, tol=1.0, min_iters=2)
    tight = pcg(mvm, y, pre.solve, max_iters=200, tol=1e-8, min_iters=2)
    assert int(loose.iterations[0]) < int(tight.iterations[0])


# ---------------------------------------------------------------------------
# warm starts (x0) — property tests + the x0=None bitwise guarantee
# ---------------------------------------------------------------------------
#
# _golden_pcg_* are VERBATIM frozen copies of the loops as they stood before
# the x0 argument existed (the "pre-PR" reference). They pin the guarantee
# that threading x0 through the solver changed nothing when x0 is None: the
# live solver must reproduce their solution AND the alpha/beta/rz0 traces
# (which the SLQ log-determinant consumes) bitwise.


def _golden_safe_div(num, den):
    ok = jnp.abs(den) > 1e-30
    return jnp.where(ok, num / jnp.where(ok, den, 1.0), 0.0)


def _golden_pcg_standard(mvm, B, precond_solve, max_iters, min_iters, tol):
    dtype = B.dtype
    allreduce = lambda x: x

    def vdot(a, b):
        return allreduce(jnp.sum(a * b, axis=0))

    u = jnp.zeros_like(B)
    r = B
    z = precond_solve(r)
    init = allreduce(jnp.stack([jnp.sum(r * z, 0), jnp.sum(B * B, 0)]))
    rz, b_norm2 = init[0], jnp.maximum(init[1], 1e-30)
    rz0 = rz
    p = z

    def body(carry, j):
        u, r, z, p, rz = carry
        Kp = mvm(p)
        red1 = allreduce(jnp.stack([jnp.sum(p * Kp, 0), jnp.sum(r * r, 0)]))
        pKp, r_norm2 = red1[0], red1[1]
        rel = jnp.sqrt(r_norm2 / b_norm2)
        active = (rel > tol) | (j < min_iters)
        alpha = jnp.where(active, _golden_safe_div(rz, pKp), 0.0)
        u = u + alpha * p
        r = r - alpha * Kp
        z_new = precond_solve(r)
        rz_new = vdot(r, z_new)
        beta = jnp.where(active, _golden_safe_div(rz_new, rz), 0.0)
        p = jnp.where(active, z_new + beta * p, p)
        z = jnp.where(active, z_new, z)
        rz = jnp.where(active, rz_new, rz)
        return (u, r, z, p, rz), (alpha.astype(dtype), beta.astype(dtype), active)

    from repro.models.runtime_flags import layer_scan_unroll
    (u, r, _, _, _), (alphas, betas, actives) = jax.lax.scan(
        body, (u, r, z, p, rz), jnp.arange(max_iters),
        unroll=layer_scan_unroll())
    rel = jnp.sqrt(vdot(r, r) / b_norm2)
    iters = jnp.sum(actives, axis=0)
    return u, alphas, betas, actives, rz0, rel, iters


def _golden_pcg_pipelined(mvm, B, precond_solve, max_iters, min_iters, tol):
    dtype = B.dtype
    allreduce = lambda x: x

    def fused(r, u, w):
        part = jnp.stack([jnp.sum(r * u, 0), jnp.sum(w * u, 0), jnp.sum(r * r, 0)])
        red = allreduce(part)
        return red[0], red[1], red[2]

    x = jnp.zeros_like(B)
    r = B
    b_norm2 = jnp.maximum(allreduce(jnp.sum(B * B, 0)), 1e-30)
    u = precond_solve(r)
    w = mvm(u)
    gamma, delta, rr = fused(r, u, w)
    rz0 = gamma
    p = jnp.zeros_like(B)
    s = jnp.zeros_like(B)
    alpha_prev = jnp.ones_like(gamma)
    gamma_prev = jnp.ones_like(gamma)

    def body(carry, j):
        x, r, u, w, p, s, gamma, delta, rr, gamma_prev, alpha_prev = carry
        rel = jnp.sqrt(rr / b_norm2)
        active = (rel > tol) | (j < min_iters)
        first = j == 0
        beta = jnp.where(first, 0.0, _golden_safe_div(gamma, gamma_prev))
        denom = delta - beta * gamma / jnp.where(first, 1.0, alpha_prev)
        alpha = jnp.where(active, _golden_safe_div(gamma, denom), 0.0)
        beta = jnp.where(active, beta, 0.0)
        p = jnp.where(active, u + beta * p, p)
        s = jnp.where(active, w + beta * s, s)
        x = x + alpha * p
        r = r - alpha * s
        u_new = precond_solve(r)
        w_new = mvm(u_new)
        gamma_new, delta_new, rr_new = fused(r, u_new, w_new)
        u = jnp.where(active, u_new, u)
        w = jnp.where(active, w_new, w)
        gamma_prev_n = jnp.where(active, gamma, gamma_prev)
        alpha_prev_n = jnp.where(active, alpha, alpha_prev)
        gamma = jnp.where(active, gamma_new, gamma)
        delta = jnp.where(active, delta_new, delta)
        rr = jnp.where(active, rr_new, rr)
        return ((x, r, u, w, p, s, gamma, delta, rr, gamma_prev_n, alpha_prev_n),
                (alpha.astype(dtype), beta.astype(dtype), active))

    from repro.models.runtime_flags import layer_scan_unroll
    carry = (x, r, u, w, p, s, gamma, delta, rr, gamma_prev, alpha_prev)
    (x, r, *rest), (alphas, betas, actives) = jax.lax.scan(
        body, carry, jnp.arange(max_iters), unroll=layer_scan_unroll())
    rel = jnp.sqrt(jnp.sum(r * r, 0) / b_norm2)
    iters = jnp.sum(actives, axis=0)
    return x, alphas, betas, actives, rz0, rel, iters


_GOLDEN = {"standard": _golden_pcg_standard, "pipelined": _golden_pcg_pipelined}


@settings(deadline=None, max_examples=6)
@given(seed=st.integers(0, 2**16), t=st.integers(1, 3),
       method=st.sampled_from(["standard", "pipelined"]),
       tol=st.sampled_from([1.0, 1e-2, 1e-8]))
def test_pcg_x0_none_bitwise_matches_pre_pr_loop(seed, t, method, tol):
    """Property: x0=None (and x0=0, since K @ 0 == 0 exactly) reproduces the
    pre-x0 loop BITWISE — solution and the alpha/beta/active/rz0 traces the
    SLQ log-determinant estimator consumes."""
    rng = np.random.default_rng(seed)
    X, params, Khat, mvm = _setup(rng, n=72, noise=0.4)
    B = jnp.asarray(rng.normal(size=(72, t)))
    pre = make_preconditioner("matern32", X, params, 20)
    golden = _GOLDEN[method](mvm, B, pre.solve, 40, 3, tol)
    for x0 in (None, jnp.zeros_like(B)):
        res = pcg(mvm, B, pre.solve, max_iters=40, min_iters=3, tol=tol,
                  method=method, x0=x0)
        for got, want, name in zip(
                (res.solution, res.alphas, res.betas, res.active,
                 res.rz0, res.iterations),
                (golden[0], golden[1], golden[2], golden[3],
                 golden[4], golden[6]),
                ("solution", "alphas", "betas", "active", "rz0", "iters")):
            assert np.array_equal(np.asarray(got), np.asarray(want)), (
                method, "x0=0" if x0 is not None else "x0=None", name)


@settings(deadline=None, max_examples=8)
@given(seed=st.integers(0, 2**16), t=st.integers(1, 3),
       method=st.sampled_from(["standard", "pipelined"]),
       scale=st.floats(0.1, 10.0))
def test_pcg_arbitrary_x0_same_solution(seed, t, method, scale):
    """Property: an ARBITRARY initial guess converges to the zero-start
    solution at equal (tight) tolerance — warm starts change iteration
    counts, never the answer."""
    rng = np.random.default_rng(seed)
    X, params, Khat, mvm = _setup(rng, n=64, noise=0.5)
    B = jnp.asarray(rng.normal(size=(64, t)))
    x0 = jnp.asarray(scale * rng.normal(size=(64, t)))
    pre = make_preconditioner("matern32", X, params, 20)
    kw = dict(max_iters=200, min_iters=3, tol=1e-11, method=method)
    res_cold = pcg(mvm, B, pre.solve, **kw)
    res_warm = pcg(mvm, B, pre.solve, x0=x0, **kw)
    np.testing.assert_allclose(np.asarray(res_warm.solution),
                               np.asarray(res_cold.solution), atol=1e-6)
    # and both really solve the system
    np.testing.assert_allclose(np.asarray(res_warm.solution),
                               np.asarray(jnp.linalg.solve(Khat, B)),
                               atol=1e-5)


def test_pcg_near_converged_x0_exits_at_min_iters(rng):
    """Seeding with the exact solution leaves nothing to do: the relative
    residual collapses immediately and only the min_iters floor is applied."""
    X, params, Khat, mvm = _setup(rng)
    B = jnp.asarray(rng.normal(size=(X.shape[0], 2)))
    exact = jnp.linalg.solve(Khat, B)
    pre = make_preconditioner("matern32", X, params, 30)
    cold = pcg(mvm, B, pre.solve, max_iters=150, min_iters=2, tol=1e-8)
    warm = pcg(mvm, B, pre.solve, max_iters=150, min_iters=2, tol=1e-8,
               x0=exact)
    assert int(np.max(np.asarray(warm.iterations))) == 2
    assert int(np.min(np.asarray(cold.iterations))) > 2
    np.testing.assert_allclose(np.asarray(warm.solution), np.asarray(exact),
                               atol=1e-8)


def test_pcg_state_carries_solutions(rng):
    """PCGResult.state is the warm-start handle for the next call."""
    X, params, Khat, mvm = _setup(rng)
    B = jnp.asarray(rng.normal(size=(X.shape[0], 2)))
    res = pcg(mvm, B, None, max_iters=60, min_iters=3, tol=1e-6)
    state = res.state
    assert state.probes is None
    np.testing.assert_array_equal(np.asarray(state.solutions),
                                  np.asarray(res.solution))
    warm = pcg(mvm, B, None, max_iters=60, min_iters=2, tol=1e-6,
               x0=state.solutions)
    assert int(np.max(np.asarray(warm.iterations))) <= \
        int(np.max(np.asarray(res.iterations)))


# ---------------------------------------------------------------------------
# batched multi-RHS solves through KernelOperators (the fused-step surface)
# ---------------------------------------------------------------------------
#
# The MLL forward batches y and all SLQ probes into ONE (n, t) mBCG solve;
# on fused-capable operators each iteration is a single kernel launch that
# also produces the CG reductions. These properties pin the two guarantees
# that make that safe: (1) columns never couple — a batched solve equals t
# independent single-RHS solves; (2) the fused step is an implementation
# detail — opting out (fused=False) changes nothing beyond reduction
# summation order.

from repro.core import OperatorConfig, make_operator

OP_BACKENDS = ("dense", "partitioned", "pallas", "blocksparse")


def _operator(backend, X, params):
    plan = None
    if backend == "blocksparse":
        from repro.sparse import build_plan
        plan = build_plan("matern32", X, params, tile=32)
    return make_operator(
        OperatorConfig(kernel="matern32", backend=backend, row_block=32,
                       interpret=True, plan=plan), X, params)


@settings(deadline=None, max_examples=3)
@given(seed=st.integers(0, 2**16), t=st.integers(1, 8),
       method=st.sampled_from(["standard", "pipelined"]))
def test_batched_multirhs_matches_per_column(seed, t, method):
    """Property: one batched (n, t) solve == t single-RHS solves, column
    for column, <= 2e-5 in fp32 — on every backend, 1-8 RHS, both CG
    variants (the pallas rows run the fused megakernel step). Backends are
    looped in the body (not parametrize: the hypothesis shim's wrapper
    hides fixture-visible parameters from pytest)."""
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(64, 3)), jnp.float32)
    params = init_params(noise=0.4, dtype=jnp.float32)
    B = jnp.asarray(rng.normal(size=(64, t)), jnp.float32)
    kw = dict(max_iters=120, min_iters=3, tol=1e-7, method=method)
    for backend in OP_BACKENDS:
        op = _operator(backend, X, params)
        batched = pcg(op, B, None, **kw)
        for j in range(t):
            single = pcg(op, B[:, j:j + 1], None, **kw)
            np.testing.assert_allclose(
                np.asarray(batched.solution[:, j]),
                np.asarray(single.solution[:, 0]), atol=2e-5,
                err_msg=f"{backend} col {j}/{t}")


@settings(deadline=None, max_examples=4)
@given(seed=st.integers(0, 2**16), t=st.integers(1, 8),
       method=st.sampled_from(["standard", "pipelined"]))
def test_fused_step_matches_classic_step(seed, t, method):
    """Property: the fused matvec+reductions step (pallas megakernel) and
    the classic two-launch step produce the same solve — solution AND the
    alpha/beta traces the SLQ log-determinant consumes."""
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(64, 3)), jnp.float32)
    params = init_params(noise=0.4, dtype=jnp.float32)
    op = _operator("pallas", X, params)
    assert op.supports_fused_step
    B = jnp.asarray(rng.normal(size=(64, t)), jnp.float32)
    kw = dict(max_iters=100, min_iters=5, tol=1e-6, method=method)
    fused = pcg(op, B, None, fused=True, **kw)
    classic = pcg(op, B, None, fused=False, **kw)
    np.testing.assert_allclose(np.asarray(fused.solution),
                               np.asarray(classic.solution), atol=2e-5)
    # coefficient traces compare over the forced-active prefix only: past
    # min_iters the convergence mask may flip one iteration apart between
    # the two reduction orders, zeroing one trace but not the other
    np.testing.assert_allclose(np.asarray(fused.alphas)[:5],
                               np.asarray(classic.alphas)[:5],
                               rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(np.asarray(fused.betas)[:5],
                               np.asarray(classic.betas)[:5],
                               rtol=1e-3, atol=1e-5)


def test_operator_solve_matches_direct(rng):
    """Batched operator solves land on the dense answer (fused default on
    the pallas backend, base-class fallback elsewhere)."""
    X = jnp.asarray(rng.normal(size=(72, 3)), jnp.float32)
    params = init_params(noise=0.4, dtype=jnp.float32)
    B = jnp.asarray(rng.normal(size=(72, 4)), jnp.float32)
    Khat64 = dense_khat("matern32", jnp.asarray(X, jnp.float64),
                        jax.tree.map(lambda a: jnp.asarray(a, jnp.float64),
                                     params))
    direct = np.asarray(jnp.linalg.solve(Khat64, jnp.asarray(B, jnp.float64)))
    for backend in OP_BACKENDS:
        op = _operator(backend, X, params)
        res = pcg(op, B, None, max_iters=200, min_iters=3, tol=1e-7)
        np.testing.assert_allclose(np.asarray(res.solution), direct,
                                   atol=3e-4, err_msg=backend)
