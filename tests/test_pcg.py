"""PCG/mBCG: solve accuracy, pipelined equivalence, convergence masking."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep; deterministic fallback (conftest dir is on sys.path)
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import (
    dense_khat, init_params, kmvm, make_preconditioner, pcg,
)

P64 = dict(dtype=jnp.float64)


def _setup(rng, n=120, d=3, noise=0.3):
    X = jnp.asarray(rng.normal(size=(n, d)))
    params = init_params(noise=noise, **P64)
    Khat = dense_khat("matern32", X, params)
    mvm = lambda V: kmvm("matern32", X, V, params, row_block=32)
    return X, params, Khat, mvm


def test_pcg_matches_direct_solve(rng):
    X, params, Khat, mvm = _setup(rng)
    B = jnp.asarray(rng.normal(size=(X.shape[0], 3)))
    pre = make_preconditioner("matern32", X, params, 30)
    res = pcg(mvm, B, pre.solve, max_iters=200, tol=1e-10, min_iters=5)
    direct = jnp.linalg.solve(Khat, B)
    np.testing.assert_allclose(np.asarray(res.solution), np.asarray(direct),
                               atol=1e-6)
    assert np.all(np.asarray(res.rel_residual) < 1e-8)


def test_pipelined_equals_standard(rng):
    X, params, Khat, mvm = _setup(rng)
    B = jnp.asarray(rng.normal(size=(X.shape[0], 2)))
    pre = make_preconditioner("matern32", X, params, 30)
    r1 = pcg(mvm, B, pre.solve, max_iters=150, tol=1e-10, min_iters=5)
    r2 = pcg(mvm, B, pre.solve, max_iters=150, tol=1e-10, min_iters=5,
             method="pipelined")
    np.testing.assert_allclose(np.asarray(r1.solution),
                               np.asarray(r2.solution), atol=1e-6)


def test_preconditioner_reduces_iterations(rng):
    X, params, Khat, mvm = _setup(rng, n=200, noise=0.05)
    y = jnp.asarray(rng.normal(size=(X.shape[0], 1)))
    r_no = pcg(mvm, y, None, max_iters=300, tol=1e-6, min_iters=2)
    pre = make_preconditioner("matern32", X, params, 60)
    r_pre = pcg(mvm, y, pre.solve, max_iters=300, tol=1e-6, min_iters=2)
    assert int(r_pre.iterations[0]) < int(r_no.iterations[0])


def test_convergence_masking_freezes_columns(rng):
    """A converged column's coefficients are zeroed; others keep iterating."""
    X, params, Khat, mvm = _setup(rng)
    easy = np.zeros((X.shape[0], 1))
    easy[0] = 1e-3
    hard = rng.normal(size=(X.shape[0], 1))
    B = jnp.asarray(np.concatenate([easy, hard], 1))
    res = pcg(mvm, B, None, max_iters=100, tol=1e-4, min_iters=2)
    assert int(res.iterations[0]) <= int(res.iterations[1])
    # frozen iterations have alpha == 0
    n_active0 = int(res.iterations[0])
    assert np.allclose(np.asarray(res.alphas)[n_active0:, 0], 0.0)


def test_1d_rhs_roundtrip(rng):
    X, params, Khat, mvm = _setup(rng)
    y = jnp.asarray(rng.normal(size=X.shape[0]))
    res = pcg(mvm, y, None, max_iters=200, tol=1e-10, min_iters=5)
    assert res.solution.shape == y.shape
    np.testing.assert_allclose(np.asarray(res.solution),
                               np.asarray(jnp.linalg.solve(Khat, y)), atol=1e-6)


@settings(deadline=None, max_examples=8)
@given(seed=st.integers(0, 2**16), t=st.integers(1, 4),
       method=st.sampled_from(["standard", "pipelined"]))
def test_pcg_property_random_spd(seed, t, method):
    """Property: for any kernel SPD system, PCG @ tight tol == direct solve."""
    rng = np.random.default_rng(seed)
    X, params, Khat, mvm = _setup(rng, n=64, noise=0.5)
    B = jnp.asarray(rng.normal(size=(64, t)))
    res = pcg(mvm, B, None, max_iters=200, tol=1e-11, min_iters=5,
              method=method)
    np.testing.assert_allclose(np.asarray(res.solution),
                               np.asarray(jnp.linalg.solve(Khat, B)),
                               atol=1e-5)


def test_loose_tolerance_stops_early(rng):
    """Paper: eps = 1 training tolerance => far fewer iterations."""
    X, params, Khat, mvm = _setup(rng, n=200, noise=0.1)
    y = jnp.asarray(rng.normal(size=(X.shape[0], 1)))
    pre = make_preconditioner("matern32", X, params, 30)
    loose = pcg(mvm, y, pre.solve, max_iters=200, tol=1.0, min_iters=2)
    tight = pcg(mvm, y, pre.solve, max_iters=200, tol=1e-8, min_iters=2)
    assert int(loose.iterations[0]) < int(tight.iterations[0])
